#!/usr/bin/env python
"""Docs link / anchor / command checker (the CI ``docs`` job).

Docs rot silently: a renamed file breaks a link, a refactor moves the
line a source anchor points at, a CLI flag disappears from under a
runbook.  This checker walks ``docs/*.md`` + ``README.md`` and fails CI
when:

  1. a relative markdown link does not resolve to an existing file, or
     its ``#heading`` fragment does not match any heading in the target
     (GitHub slug rules);
  2. a backticked ``path:line`` source anchor names a missing file or a
     line past the end of that file;
  3. a command quoted in a fenced block does not run: ``python -m
     repro.X ...`` must exit 0 under ``--help`` (the entrypoint and its
     argparse surface exist), and ``python <script>.py`` scripts must at
     least byte-compile.

Run locally:  PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import py_compile
import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DOC_FILES = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
ANCHOR_RE = re.compile(r"`([A-Za-z0-9_./\-]+\.(?:py|md|yml|yaml|json|toml))"
                       r":(\d+)`")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FENCE_RE = re.compile(r"```(?:bash|sh|console)\n(.*?)```", re.DOTALL)
PYMOD_RE = re.compile(r"python\s+-m\s+([A-Za-z0-9_.]+)")
PYFILE_RE = re.compile(r"python\s+((?:examples|benchmarks|tools)/"
                       r"[A-Za-z0-9_./\-]+\.py)")


def github_slug(heading: str) -> str:
    """GitHub's heading -> anchor slug: lowercase, drop punctuation
    (keeping alphanumerics, spaces, hyphens, underscores), spaces to
    hyphens."""
    h = heading.strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def headings_of(path: Path) -> set:
    return {github_slug(m.group(1))
            for m in HEADING_RE.finditer(path.read_text())}


def check_links(md: Path, errors: list) -> None:
    text = md.read_text()
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, frag = target.partition("#")
        dest = (md.parent / path_part).resolve() if path_part else md
        if not dest.exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link {target!r}")
            continue
        if frag and dest.suffix == ".md" and \
                frag not in headings_of(dest):
            errors.append(f"{md.relative_to(ROOT)}: anchor #{frag} not a "
                          f"heading of {path_part or md.name}")


def check_source_anchors(md: Path, errors: list) -> None:
    for m in ANCHOR_RE.finditer(md.read_text()):
        rel, line = m.group(1), int(m.group(2))
        src = ROOT / rel
        if not src.exists():
            errors.append(f"{md.relative_to(ROOT)}: source anchor "
                          f"{rel}:{line} — file missing")
        elif line > len(src.read_text().splitlines()):
            errors.append(f"{md.relative_to(ROOT)}: source anchor "
                          f"{rel}:{line} — past end of file")


def check_commands(md: Path, errors: list, seen: set) -> None:
    """Every quoted command's entrypoint must still exist: ``python -m
    repro.X`` runs with --help (argparse surface intact), quoted scripts
    byte-compile.  Each target checked once across all pages."""
    text = md.read_text()
    for block in FENCE_RE.finditer(text):
        for mod in PYMOD_RE.findall(block.group(1)):
            if not mod.startswith("repro.") or mod in seen:
                continue
            seen.add(mod)
            try:
                r = subprocess.run(
                    [sys.executable, "-m", mod, "--help"],
                    capture_output=True, text=True, timeout=120,
                    cwd=ROOT, env={**__import__("os").environ,
                                   "PYTHONPATH": str(ROOT / "src")})
            except subprocess.TimeoutExpired:
                # a hanging entrypoint is a docs failure to report, not a
                # traceback that kills the whole CI job
                errors.append(f"{md.relative_to(ROOT)}: `python -m {mod} "
                              f"--help` timed out after 120s")
                continue
            if r.returncode != 0:
                errors.append(
                    f"{md.relative_to(ROOT)}: `python -m {mod} --help` "
                    f"exited {r.returncode}: {r.stderr.strip()[:200]}")
        for script in PYFILE_RE.findall(block.group(1)):
            if script in seen:
                continue
            seen.add(script)
            path = ROOT / script
            if not path.exists():
                errors.append(f"{md.relative_to(ROOT)}: quoted script "
                              f"{script} missing")
                continue
            try:
                py_compile.compile(str(path), doraise=True)
            except py_compile.PyCompileError as e:
                errors.append(f"{md.relative_to(ROOT)}: quoted script "
                              f"{script} does not compile: {e}")


def main() -> int:
    errors: list = []
    seen_cmds: set = set()
    for md in DOC_FILES:
        check_links(md, errors)
        check_source_anchors(md, errors)
        check_commands(md, errors, seen_cmds)
    if errors:
        print(f"check_docs: {len(errors)} failure(s)")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"check_docs: {len(DOC_FILES)} pages OK "
          f"({len(seen_cmds)} quoted commands verified)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
