#!/usr/bin/env python3
"""Validate the continuous-batching serve smoke run (CI tier-2 gate).

    python tools/validate_serve.py --metrics M.jsonl [--run-log RUN.log]

Checks, without any third-party dependency, that the serving path
actually exercised iteration-level scheduling:

  * the metrics JSONL header carries a ``run_id``, and the stream
    contains ``serve_ttft_s`` AND ``serve_tpot_s`` observations plus a
    ``serve_occupancy`` gauge (the telemetry the replan loop rides);
  * with ``--run-log``: the driver's final JSON summary (last line)
    carries the SAME ``run_id`` as the metrics header (artifact
    attribution), reports ``occupancy > fixed_batch_occupancy`` — the
    continuous-batching win over the seed fixed-batch driver — and its
    token accounting is disjoint:
    ``generated == first_from_prefill + decoded``.

Exit 0 on pass; exit 1 with one line per violation on fail.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _load(path):
    out = []
    for line in Path(path).read_text().splitlines():
        if line.strip():
            out.append(json.loads(line))
    return out


def validate(metrics_path, run_log=None):
    errors = []
    recs = _load(metrics_path)
    header = next((r for r in recs if r.get("kind") == "header"), None)
    run_id = None
    if header is None or not header.get("run_id"):
        errors.append("metrics: no header record with a run_id")
    else:
        run_id = header["run_id"]
    names = {(r.get("kind"), r.get("name")) for r in recs}
    for kind, name in (("observe", "serve_ttft_s"),
                       ("observe", "serve_tpot_s"),
                       ("gauge", "serve_occupancy"),
                       ("gauge", "serve_queue_depth")):
        if (kind, name) not in names:
            errors.append(f"metrics: no {kind} record named {name}")
    summary = None
    if run_log:
        last = Path(run_log).read_text().strip().splitlines()[-1]
        try:
            summary = json.loads(last)
        except Exception as e:  # noqa: BLE001
            errors.append(f"run-log: last line is not the JSON summary: {e}")
        else:
            if run_id is not None and summary.get("run_id") != run_id:
                errors.append(
                    f"run-log: run_id {summary.get('run_id')!r} does not "
                    f"match metrics header {run_id!r}")
            occ = summary.get("occupancy")
            fixed = summary.get("fixed_batch_occupancy")
            if occ is None or fixed is None:
                errors.append("run-log: summary missing occupancy / "
                              "fixed_batch_occupancy")
            elif occ <= fixed:
                errors.append(
                    f"run-log: continuous-batching occupancy {occ} does "
                    f"not beat the fixed-batch baseline {fixed}")
            tok = summary.get("tokens", {})
            if tok.get("generated") != (tok.get("first_from_prefill", 0)
                                        + tok.get("decoded", -1)):
                errors.append(f"run-log: token accounting not disjoint: "
                              f"{tok}")
    return errors, summary


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--metrics", required=True)
    ap.add_argument("--run-log", default=None,
                    help="driver stdout capture; last line must be the "
                         "final JSON summary")
    args = ap.parse_args(argv)
    errors, summary = validate(args.metrics, args.run_log)
    for e in errors:
        print(f"FAIL {e}")
    if not errors:
        occ = summary.get("occupancy") if summary else "n/a"
        print(f"OK serve smoke (occupancy {occ})")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
