#!/usr/bin/env python3
"""Validate exported observability artifacts (CI tier-2 gate).

    python tools/validate_obs.py --trace T.json --metrics M.jsonl \
        [--events E.jsonl] [--expect-replan]

Checks, without any third-party dependency:

  * the trace is Chrome trace-event JSON: a ``traceEvents`` list whose
    events carry a known phase, a numeric ``ts`` (metadata excepted),
    ``dur >= 0`` on complete events, ``id`` on flow events — and the
    run-identity header under ``otherData``;
  * every metrics JSONL record matches ``tools/metrics_schema.json``
    (per-kind required field -> type map; first record must be the
    header);
  * the events JSONL is a header plus ``adapt_event`` records;
  * all supplied artifacts agree on ``run_id``;
  * with ``--expect-replan``: the trace contains BOTH lanes (process
    names ``predicted``/``observed``) and an ``adapt:migrate`` instant —
    the acceptance shape of the instrumented autopilot smoke.

Exit 0 on pass; exit 1 with one line per violation on fail.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

KNOWN_PHASES = {"X", "B", "E", "i", "I", "M", "s", "t", "f", "C",
                "b", "e", "n"}

_TYPES = {"str": str, "int": int, "number": (int, float),
          "object": dict, "null": type(None)}


def _type_ok(value, spec) -> bool:
    specs = spec if isinstance(spec, list) else [spec]
    for s in specs:
        t = _TYPES[s]
        if isinstance(value, t) and not (s in ("int", "number")
                                         and isinstance(value, bool)):
            return True
    return False


def validate_trace(path, expect_replan: bool = False):
    """Returns (errors, run_id)."""
    errors = []
    try:
        doc = json.loads(Path(path).read_text())
    except Exception as e:  # noqa: BLE001
        return [f"trace: unreadable JSON: {e}"], None
    evs = doc.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return ["trace: traceEvents missing or empty"], None
    run_id = (doc.get("otherData") or {}).get("run_id")
    if not run_id:
        errors.append("trace: otherData.run_id missing (no run identity)")
    procs, instants = set(), set()
    for i, e in enumerate(evs):
        ph = e.get("ph")
        if ph not in KNOWN_PHASES:
            errors.append(f"trace[{i}]: unknown phase {ph!r}")
            continue
        if ph != "M" and not isinstance(e.get("ts"), (int, float)):
            errors.append(f"trace[{i}]: {ph!r} event without numeric ts")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"trace[{i}]: complete event needs dur >= 0")
        if ph in ("s", "t", "f") and "id" not in e:
            errors.append(f"trace[{i}]: flow event without id")
        if ph == "M" and e.get("name") == "process_name":
            procs.add(e.get("args", {}).get("name", "").split(" ")[0])
        if ph == "i":
            instants.add(e.get("name"))
    if expect_replan:
        for lane in ("predicted", "observed"):
            if lane not in procs:
                errors.append(f"trace: {lane} lane missing (processes: "
                              f"{sorted(procs)})")
        if "adapt:migrate" not in instants:
            errors.append(f"trace: no adapt:migrate instant (instants: "
                          f"{sorted(instants)})")
    return errors, run_id


def validate_metrics(path, schema_path=None):
    """Returns (errors, run_id)."""
    schema_path = schema_path or Path(__file__).parent / \
        "metrics_schema.json"
    schema = json.loads(Path(schema_path).read_text())["kinds"]
    errors = []
    run_id = None
    lines = Path(path).read_text().splitlines()
    if not lines:
        return ["metrics: empty stream"], None
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except Exception as e:  # noqa: BLE001
            errors.append(f"metrics[{i}]: unparseable: {e}")
            continue
        kind = rec.get("kind")
        if i == 0:
            if kind != "header":
                errors.append("metrics[0]: first record must be the "
                              f"header, got kind={kind!r}")
            run_id = rec.get("run_id")
        if kind not in schema:
            errors.append(f"metrics[{i}]: unknown kind {kind!r}")
            continue
        for field, spec in schema[kind].items():
            if field not in rec:
                errors.append(f"metrics[{i}] ({kind}): missing {field!r}")
            elif not _type_ok(rec[field], spec):
                errors.append(f"metrics[{i}] ({kind}): {field!r} has "
                              f"type {type(rec[field]).__name__}, "
                              f"expected {spec}")
    return errors, run_id


def validate_events(path):
    """Returns (errors, run_id)."""
    errors = []
    run_id = None
    for i, line in enumerate(Path(path).read_text().splitlines()):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except Exception as e:  # noqa: BLE001
            errors.append(f"events[{i}]: unparseable: {e}")
            continue
        kind = rec.get("kind")
        if i == 0 and kind == "header":
            run_id = rec.get("run_id")
            continue
        if kind != "adapt_event":
            errors.append(f"events[{i}]: unknown kind {kind!r}")
        elif not all(k in rec for k in ("step", "action", "reason")):
            errors.append(f"events[{i}]: adapt_event missing "
                          "step/action/reason")
    return errors, run_id


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", default=None)
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--events", default=None)
    ap.add_argument("--schema", default=None,
                    help="metrics schema (default tools/metrics_schema"
                         ".json)")
    ap.add_argument("--expect-replan", action="store_true",
                    help="require predicted+observed lanes and an "
                         "adapt:migrate instant in the trace")
    args = ap.parse_args(argv)
    if not (args.trace or args.metrics or args.events):
        ap.error("nothing to validate: pass --trace/--metrics/--events")
    errors = []
    run_ids = {}
    if args.trace:
        errs, rid = validate_trace(args.trace, args.expect_replan)
        errors += errs
        run_ids["trace"] = rid
    if args.metrics:
        errs, rid = validate_metrics(args.metrics, args.schema)
        errors += errs
        run_ids["metrics"] = rid
    if args.events:
        errs, rid = validate_events(args.events)
        errors += errs
        run_ids["events"] = rid
    ids = {k: v for k, v in run_ids.items() if v}
    if len(set(ids.values())) > 1:
        errors.append(f"run identity mismatch across artifacts: {ids}")
    for e in errors:
        print(f"FAIL {e}")
    if not errors:
        checked = ", ".join(k for k, v in run_ids.items()
                            if v is not None or k in run_ids)
        print(f"OK {checked} (run {next(iter(ids.values()), '?')})")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
