#!/usr/bin/env python3
"""Validate the elastic-membership smoke run (CI tier-2 gate).

    python tools/validate_elastic.py --events E.jsonl [--run-log RUN.log]

Checks, without any third-party dependency, that the node-loss/rejoin
smoke actually exercised the elastic path:

  * the events JSONL contains a ``node-lost`` AND a ``node-joined``
    adapt event, each preceded by its forced ``replan``;
  * at least two ``migrate`` events (one per membership edit);
  * with ``--run-log``: the driver's final JSON summary (last line)
    reports ``migrations.memory >= 2`` and ``migrations.checkpoint == 0``
    — both edits were absorbed in memory, no restart.

Exit 0 on pass; exit 1 with one line per violation on fail.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _load_events(path):
    events = []
    for line in Path(path).read_text().splitlines():
        if not line.strip():
            continue
        rec = json.loads(line)
        if rec.get("kind") == "adapt_event":
            events.append(rec)
    return events


def validate(events_path, run_log=None):
    errors = []
    events = _load_events(events_path)
    actions = [e.get("action") for e in events]
    for want in ("node-lost", "node-joined"):
        if want not in actions:
            errors.append(f"events: no {want} event (actions: {actions})")
    if actions.count("migrate") < 2:
        errors.append(f"events: expected >= 2 migrate events, got "
                      f"{actions.count('migrate')} (actions: {actions})")
    # each membership edit is a FORCED replan: the searched replan event
    # must precede its node-lost / node-joined application
    for member in ("node-lost", "node-joined"):
        if member in actions:
            i = actions.index(member)
            if "replan" not in actions[:i]:
                errors.append(f"events: {member} not preceded by a "
                              f"replan (actions: {actions})")
    if run_log:
        last = Path(run_log).read_text().strip().splitlines()[-1]
        try:
            summary = json.loads(last)
        except Exception as e:  # noqa: BLE001
            errors.append(f"run-log: last line is not the JSON summary: "
                          f"{e}")
        else:
            mig = summary.get("migrations", {})
            if mig.get("memory", 0) < 2:
                errors.append(f"run-log: expected >= 2 in-memory "
                              f"migrations, got {mig}")
            if mig.get("checkpoint", 0) != 0:
                errors.append(f"run-log: expected 0 checkpoint-path "
                              f"migrations (restartless), got {mig}")
    return errors, actions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--events", required=True)
    ap.add_argument("--run-log", default=None,
                    help="driver stdout capture; last line must be the "
                         "final JSON summary")
    args = ap.parse_args(argv)
    errors, actions = validate(args.events, args.run_log)
    for e in errors:
        print(f"FAIL {e}")
    if not errors:
        print(f"OK elastic smoke ({len(actions)} adapt events: "
              f"{actions})")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
